"""Metadata (mpool) utilization -- paper Fig 13a + Table 2's lightweight claim.

Paper: 400 MB reserved, 127.33 MB average used (46.69% peak-relative),
68.53% full pages (EPT/IOMMU tables) vs 31.47% slab; total resource
overhead 1.2% reserved / 0.38% live.
"""
from __future__ import annotations

from repro.core.config import LRUConfig, TaijiConfig
from repro.core.system import TaijiSystem

from .workload import fill_system


def run(verbose: bool = True) -> dict:
    cfg = TaijiConfig(ms_bytes=128 * 1024, mps_per_ms=32, n_phys_ms=64,
                      overcommit_ratio=0.5, mpool_reserve_ms=4,
                      lru=LRUConfig(stabilize_scans=1, workers=1))
    system = TaijiSystem(cfg)
    fill_system(system, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=17)
    st = system.mpool.stats()
    managed_bytes = (cfg.n_phys_ms - cfg.mpool_reserve_ms) * cfg.ms_bytes
    result = {
        "reserved_bytes": st["reserved_bytes"],
        "used_bytes": st["used_bytes"],
        "peak_bytes": st["peak_bytes"],
        "utilization": st["utilization"],
        "full_page_fraction": st["full_page_fraction"],
        "slab_fraction": st["slab_fraction"],
        "overhead_live": st["used_bytes"] / managed_bytes,
        "overhead_reserved": st["reserved_bytes"] / managed_bytes,
    }
    if verbose:
        print(f"mpool: {st['used_bytes']/1024:.1f} KiB used of "
              f"{st['reserved_bytes']/1024:.1f} KiB reserved "
              f"({st['utilization']*100:.1f}%; paper 46.69% peak-relative)")
        print(f"full pages {st['full_page_fraction']*100:.1f}% / slab "
              f"{st['slab_fraction']*100:.1f}% (paper 68.53% / 31.47%)")
        print(f"overhead: {result['overhead_live']*100:.2f}% live / "
              f"{result['overhead_reserved']*100:.2f}% reserved "
              f"(paper 0.38% / 1.2%)")
    system.close()
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        ("mpool_utilization", r["utilization"], "paper~0.47"),
        ("mpool_overhead_live", r["overhead_live"], "paper=0.0038"),
        ("mpool_full_page_fraction", r["full_page_fraction"], "paper=0.6853"),
    ]


if __name__ == "__main__":
    run()
