"""Metadata (mpool) utilization -- paper Fig 13a + Table 2's lightweight claim.

Paper: 400 MB reserved, 127.33 MB average used (46.69% peak-relative),
68.53% full pages (EPT/IOMMU tables) vs 31.47% slab; total resource
overhead 1.2% reserved / 0.38% live.
"""
from __future__ import annotations

from repro.core.config import LRUConfig, TaijiConfig
from repro.core.system import TaijiSystem

from .workload import fill_system


def _age_and_reclaim(system, cfg) -> None:
    for _ in range(4 * cfg.lru.stabilize_scans + 2):
        for w in range(cfg.lru.workers):
            system.lru.scan_shard(w, cfg.lru.workers)
    while system.engine.reclaim_round() > 0:
        pass


def run(verbose: bool = True) -> dict:
    cfg = TaijiConfig(ms_bytes=128 * 1024, mps_per_ms=32, n_phys_ms=64,
                      overcommit_ratio=0.5, mpool_reserve_ms=4,
                      lru=LRUConfig(stabilize_scans=1, workers=1))
    system = TaijiSystem(cfg)
    # The paper's 46.69% is *average used over peak used* across a load
    # cycle (400 MB reserved, 127.33 MB average, "peak-relative") --
    # metadata tracks the machine's swap population, and the average
    # sits mid-cycle. The old row divided a single post-fill sample by
    # the full reserve, which on this smoke geometry pinned it at ~0.03
    # (a bare fill touches only the EPT full pages; no MS has ever
    # swapped, so no req-tree descriptor exists). Drive a full lifecycle
    # -- empty, fill, age + reclaim the elastic overhang through the
    # real swap path (one descriptor per swapped MS), release half the
    # guest set, refill -- sampling used bytes at each phase, and report
    # the paper's metric over those samples.
    samples = [system.mpool.stats()["used_bytes"]]          # empty system
    data = fill_system(system, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=17)
    samples.append(system.mpool.stats()["used_bytes"])      # filled, resident
    _age_and_reclaim(system, cfg)
    samples.append(system.mpool.stats()["used_bytes"])      # swapped (peak)
    gfns = sorted(data)
    for g in gfns[: len(gfns) // 2]:                        # load trough
        system.guest_free_ms(g)
    samples.append(system.mpool.stats()["used_bytes"])
    for _ in range(len(gfns) // 4):                         # partial refill
        system.guest_alloc_ms()
    _age_and_reclaim(system, cfg)
    samples.append(system.mpool.stats()["used_bytes"])
    st = system.mpool.stats()
    managed_bytes = (cfg.n_phys_ms - cfg.mpool_reserve_ms) * cfg.ms_bytes
    avg_used = sum(samples) / len(samples)
    result = {
        "reserved_bytes": st["reserved_bytes"],
        "used_bytes": st["used_bytes"],
        "peak_bytes": st["peak_bytes"],
        "used_samples": samples,
        "utilization": avg_used / max(1, st["peak_bytes"]),
        "utilization_reserved": st["used_bytes"] / st["reserved_bytes"],
        "full_page_fraction": st["full_page_fraction"],
        "slab_fraction": st["slab_fraction"],
        "overhead_live": st["used_bytes"] / managed_bytes,
        "overhead_reserved": st["reserved_bytes"] / managed_bytes,
    }
    if verbose:
        print(f"mpool: {st['used_bytes']/1024:.1f} KiB used of "
              f"{st['reserved_bytes']/1024:.1f} KiB reserved; "
              f"avg/peak over lifecycle "
              f"{result['utilization']*100:.1f}% "
              f"(paper 46.69% peak-relative)")
        print(f"full pages {st['full_page_fraction']*100:.1f}% / slab "
              f"{st['slab_fraction']*100:.1f}% (paper 68.53% / 31.47%)")
        print(f"overhead: {result['overhead_live']*100:.2f}% live / "
              f"{result['overhead_reserved']*100:.2f}% reserved "
              f"(paper 0.38% / 1.2%)")
    system.close()
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        # avg-used/peak-used across an empty->fill->reclaim->release->
        # refill lifecycle: the paper's own "46.69% peak-relative"
        # metric (was used/reserved of one post-fill sample, which this
        # smoke geometry pinned at a meaningless ~0.03)
        ("mpool_utilization", r["utilization"],
         f"paper~0.47_avg/peak_lifecycle_"
         f"reserved_rel={r['utilization_reserved']:.4f}"),
        ("mpool_overhead_live", r["overhead_live"], "paper=0.0038"),
        ("mpool_full_page_fraction", r["full_page_fraction"], "paper=0.6853"),
    ]


if __name__ == "__main__":
    run()
