"""Overcommit benefit -- paper §5.3.3 / Fig 13b.

Paper: 32 GB + 16 GB virtual (50% elasticity); swapping 8,000 MSes frees
15.6 GB stored in only 1.73 GB => 9x overselling gain; benefit-to-cost
vs metadata 125.5x (live) / 39x (reserved).
"""
from __future__ import annotations

from repro.core.config import LRUConfig, SwapConfig, TaijiConfig
from repro.core.system import TaijiSystem

from .workload import fill_system


def run(verbose: bool = True, smoke: bool = False,
        batched: bool = True) -> dict:
    import time as _time

    cfg = TaijiConfig(ms_bytes=(32 * 1024 if smoke else 128 * 1024),
                      mps_per_ms=32, n_phys_ms=32 if smoke else 64,
                      overcommit_ratio=0.5, mpool_reserve_ms=4,
                      lru=LRUConfig(stabilize_scans=1, workers=1),
                      swap=SwapConfig(batch_enabled=batched))
    system = TaijiSystem(cfg)
    n_virt = cfg.n_virt_ms - cfg.mpool_reserve_ms
    t_fill0 = _time.perf_counter()
    fill_system(system, n_virt, seed=13)
    fill_s = _time.perf_counter() - t_fill0

    managed_phys = cfg.n_phys_ms - cfg.mpool_reserve_ms
    elastic_ms = n_virt - managed_phys
    m = system.metrics
    freed_bytes = m.ms_swapped_out * cfg.ms_bytes
    stored = system.backend.stored_bytes()
    mpool = system.mpool.stats()

    result = {
        "fill_s": fill_s,
        "swap_out_batches": m.swap_out_batches,
        "mean_swap_out_batch_mps": m.snapshot()["mean_swap_out_batch_mps"],
        "virtual_ms": n_virt,
        "physical_ms": managed_phys,
        "elasticity": n_virt / managed_phys - 1.0,
        "ms_swapped_out": m.ms_swapped_out,
        "freed_bytes": freed_bytes,
        "backend_stored_bytes": stored,
        "overselling_gain": freed_bytes / max(1, stored),
        "metadata_used_bytes": mpool["used_bytes"],
        "metadata_reserved_bytes": mpool["reserved_bytes"],
        "benefit_vs_metadata_used": freed_bytes / max(1, mpool["used_bytes"]),
        "benefit_vs_metadata_reserved": freed_bytes / max(1, mpool["reserved_bytes"]),
    }
    if verbose:
        print(f"elasticity: +{result['elasticity']*100:.0f}% "
              f"({n_virt} virtual / {managed_phys} physical MSs; paper +50%)")
        print(f"freed {freed_bytes/1e6:.1f} MB stored in {stored/1e6:.2f} MB "
              f"=> overselling gain {result['overselling_gain']:.1f}x (paper 9x)")
        print(f"benefit-to-cost: {result['benefit_vs_metadata_used']:.0f}x live / "
              f"{result['benefit_vs_metadata_reserved']:.0f}x reserved "
              f"(paper 125.5x / 39x)")
    system.close()
    return result


def _best_fill(smoke: bool, batched: bool) -> dict:
    # the first invocation pays numpy/zlib warmup; min-of-two removes the
    # bias where it's cheap (smoke). The full config runs each mode once,
    # scalar first, so any residual warmup biases *against* the batched
    # speedup row rather than for it.
    runs = [run(verbose=False, smoke=smoke, batched=batched)
            for _ in range(2 if smoke else 1)]
    return min(runs, key=lambda r: r["fill_s"])


def rows(smoke: bool = False) -> list:
    r_scalar = _best_fill(smoke, batched=False)
    r = _best_fill(smoke, batched=True)
    fill_speedup = r_scalar["fill_s"] / max(r["fill_s"], 1e-9)
    return [
        ("overcommit_elasticity", r["elasticity"], "paper>=0.50"),
        ("overselling_gain", r["overselling_gain"], "paper=9x"),
        ("benefit_vs_metadata_used", r["benefit_vs_metadata_used"], "paper=125.5x"),
        ("overcommit_fill_batched_speedup", fill_speedup,
         f"scalar={r_scalar['fill_s']:.2f}s_batched={r['fill_s']:.2f}s"),
        ("mean_swap_out_batch_mps", r["mean_swap_out_batch_mps"],
         f"batches={r['swap_out_batches']}"),
    ]


if __name__ == "__main__":
    run()
