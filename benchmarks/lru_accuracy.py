"""Multi-level LRU cold-page identification -- paper Fig 15b / 14c.

Paper: cluster average cold-memory ratio 52.79%; most-utilized nodes stay
above 30%. We drive a known hot/cold access pattern and measure how
accurately the multi-level sets recover it (precision/recall of the cold
set) plus the identified cold ratio.
"""
from __future__ import annotations

import numpy as np

from repro.core.config import LRUConfig, TaijiConfig
from repro.core.system import TaijiSystem


def run(n_ms: int = 96, hot_fraction: float = 0.45, scans: int = 12,
        verbose: bool = True) -> dict:
    cfg = TaijiConfig(ms_bytes=16 * 1024, mps_per_ms=8, n_phys_ms=n_ms + 4,
                      overcommit_ratio=0.1, mpool_reserve_ms=4,
                      lru=LRUConfig(stabilize_scans=2, workers=2))
    system = TaijiSystem(cfg)
    rng = np.random.default_rng(5)
    gfns = [system.guest_alloc_ms() for _ in range(n_ms)]
    hot = set(rng.choice(gfns, size=int(n_ms * hot_fraction), replace=False).tolist())

    for _ in range(scans):
        # hot pages touched every round (with one transient cold touch to
        # exercise the smoothing), cold pages idle
        for g in hot:
            system.virt.table.mark_accessed(g)
        transient = int(rng.choice(gfns))
        system.virt.table.mark_accessed(transient)
        for w in range(cfg.lru.workers):
            system.lru.scan_shard(w, cfg.lru.workers)

    from repro.core.lru import INACTIVE
    identified_cold = {g for g in gfns
                       if (system.lru.level_of(g) or 0) >= INACTIVE}
    actual_cold = set(gfns) - hot
    tp = len(identified_cold & actual_cold)
    result = {
        "cold_ratio_identified": len(identified_cold) / n_ms,
        "cold_ratio_actual": len(actual_cold) / n_ms,
        "precision": tp / max(1, len(identified_cold)),
        "recall": tp / max(1, len(actual_cold)),
    }
    if verbose:
        print(f"identified cold ratio: {result['cold_ratio_identified']*100:.1f}% "
              f"(actual {result['cold_ratio_actual']*100:.1f}%; paper avg 52.79%)")
        print(f"precision={result['precision']*100:.1f}%  "
              f"recall={result['recall']*100:.1f}%")
    system.close()
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        ("lru_cold_ratio", r["cold_ratio_identified"],
         f"actual={r['cold_ratio_actual']:.3f}"),
        ("lru_precision", r["precision"], f"recall={r['recall']:.3f}"),
    ]


if __name__ == "__main__":
    run()
